//! End-to-end validation driver (EXPERIMENTS.md §E2E).
//!
//! Part A — real numerics: run the tiny ViT (4 layers, d=128, ~0.8 M
//! params, weights baked at AOT time) through the PJRT artifact on a
//! batch of fresh synthetic "images", check logits are finite, stable and
//! match the JAX golden evaluation; time the request path. Skipped with a
//! note when the artifacts or the PJRT backend are unavailable
//! (DESIGN.md §4).
//!
//! Part B — the paper's ViT-base experiment (Fig. 12/13): full-system
//! simulation with SoftEx vs software nonlinearities, reporting the
//! throughput/efficiency/latency headlines.
//!
//! Run: cargo run --release --example vit_inference

use std::time::Instant;

use softex::cluster::cores::ExpAlgo;
use softex::coordinator::{execute_trace, ExecConfig, KernelClass};
use softex::energy::{OP_EFFICIENCY, OP_THROUGHPUT};
use softex::num::bf16::quantize_slice;
use softex::report;
use softex::rng::Xoshiro256;
use softex::runtime::Engine;
use softex::workload::{trace_model, ModelConfig};

fn pjrt_tiny_vit_requests() -> softex::anyhow::Result<()> {
    let mut engine = Engine::from_default_artifacts()?;
    let cfg = ModelConfig::vit_tiny();
    let (seq, d) = (cfg.seq, cfg.d_model);

    // golden check first: the artifact reproduces the JAX evaluation
    let (err, _, _) = engine.verify_golden("vit_tiny_forward")?;
    println!("vit_tiny_forward golden max|err| = {err:.3e}");

    // serve a small batch of fresh inputs, measuring request latency
    let mut rng = Xoshiro256::new(2026);
    engine.prepare("vit_tiny_forward")?;
    let mut latencies = Vec::new();
    let mut all_logits = Vec::new();
    for _ in 0..16 {
        let tokens = quantize_slice(&rng.normal_vec_f32(seq * d, 0.5));
        let t0 = Instant::now();
        let logits = engine.run("vit_tiny_forward", &[tokens])?;
        latencies.push(t0.elapsed().as_secs_f64() * 1e3);
        assert_eq!(logits.len(), 10);
        assert!(logits.iter().all(|v| v.is_finite()));
        all_logits.push(logits);
    }
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let p50 = latencies[latencies.len() / 2];
    let p99 = latencies[latencies.len() - 1];
    println!(
        "tiny-ViT request path (PJRT CPU): 16 requests, p50 {p50:.2} ms, worst {p99:.2} ms"
    );
    // different inputs must yield different predictions somewhere
    let preds: Vec<usize> = all_logits
        .iter()
        .map(|l| {
            l.iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0
        })
        .collect();
    println!("predicted classes: {preds:?}");
    Ok(())
}

fn main() {
    // ---------------- Part A: real tiny-ViT inference ------------------
    if let Err(e) = pjrt_tiny_vit_requests() {
        println!("(PJRT part skipped: {e})");
    }

    // ---------------- Part B: ViT-base system simulation ----------------
    let vit = ModelConfig::vit_base();
    let trace = trace_model(&vit);
    let hw = execute_trace(&ExecConfig::paper_accelerated(), &trace);
    let sw = execute_trace(&ExecConfig::sw_nonlinearities(ExpAlgo::Exps), &trace);

    let mut rows = Vec::new();
    for (label, m) in [("SoftEx", &hw), ("SW (exps+sigmoid)", &sw)] {
        rows.push(vec![
            label.to_string(),
            report::f(m.seconds(&OP_THROUGHPUT) * 1e3, 1),
            report::f(m.gops(&OP_THROUGHPUT), 0),
            report::f(m.tops_per_w(&OP_EFFICIENCY), 2),
            report::pct(m.fraction(KernelClass::Softmax)),
            report::pct(m.fraction(KernelClass::Gelu)),
        ]);
    }
    println!(
        "{}",
        report::render_table(
            "ViT-base end-to-end (paper Fig. 12/13: 310 GOPS, 1.34 TOPS/W, 113 ms)",
            &["config", "ms @0.8V", "GOPS", "TOPS/W @0.55V", "softmax%", "GELU%"],
            &rows
        )
    );
    let speedup = sw.total_cycles() as f64 / hw.total_cycles() as f64;
    let eff_gain = hw.tops_per_w(&OP_EFFICIENCY) / sw.tops_per_w(&OP_EFFICIENCY);
    println!(
        "SoftEx gain: {speedup:.2}x throughput (paper: 1.58x), {eff_gain:.2}x efficiency (paper: 1.42x)"
    );
    println!("vit_inference OK");
}
