//! The paper's MobileBERT attention-layer study (Sec. VII-B-c, VII-C):
//! softmax latency/energy vs the software baselines across sequence
//! lengths, plus the full attention layer and the 24-layer model.
//!
//! Run: cargo run --release --example mobilebert_attention

use softex::cluster::cores::{softmax_sw_cycles, ExpAlgo};
use softex::coordinator::{execute_trace, ExecConfig};
use softex::energy::{energy_j, ActivityMode, OP_EFFICIENCY, OP_THROUGHPUT};
use softex::report;
use softex::runtime::Engine;
use softex::softex::{run_softmax, SoftExConfig};
use softex::workload::trace::trace_attention_core;
use softex::workload::{gen, trace_model, ModelConfig};

fn pjrt_attention_golden() -> softex::anyhow::Result<()> {
    let mut engine = Engine::from_default_artifacts()?;
    let (err, _, _) = engine.verify_golden("attention_head_128")?;
    println!("attention_head_128 artifact golden max|err| = {err:.2e}\n");
    Ok(())
}

fn main() {
    let cfg = SoftExConfig::default();

    // --- softmax kernel vs software, over sequence length ---------------
    let mut rows_out = Vec::new();
    for seq in [128usize, 256, 512] {
        let mb = ModelConfig::mobilebert(seq);
        let (rows, len) = mb.softmax_shape();
        let scores = gen::attention_scores(rows, len, seq as u64);
        let hw = run_softmax(&cfg, &scores, rows, len);
        let hw_c = hw.cycles.total();
        let sw_c = softmax_sw_cycles(ExpAlgo::Exps, rows, len);
        let e_hw = energy_j(ActivityMode::SoftmaxHw, hw_c, &OP_THROUGHPUT);
        let e_sw = energy_j(ActivityMode::SoftmaxSw, sw_c, &OP_THROUGHPUT);
        rows_out.push(vec![
            seq.to_string(),
            report::cycles(hw_c),
            report::cycles(sw_c),
            format!("{:.1}x", sw_c as f64 / hw_c as f64),
            format!("{:.1}x", e_sw / e_hw),
        ]);
    }
    println!(
        "{}",
        report::render_table(
            "Softmax: SoftEx vs 8-core exps (paper: 6.2x/15.3x @128, 10.8x/26.8x @512)",
            &["seq", "SoftEx", "sw exps", "speedup", "energy gain"],
            &rows_out
        )
    );

    // --- numerics through the PJRT path on the attention head -----------
    // (skipped with a note when artifacts/backend are unavailable)
    if let Err(e) = pjrt_attention_golden() {
        println!("(PJRT golden check skipped: {e})\n");
    }

    // --- full attention layer and full model ----------------------------
    let mb = ModelConfig::mobilebert(512);
    let hw = execute_trace(&ExecConfig::paper_accelerated(), &trace_attention_core(&mb));
    let sw = execute_trace(
        &ExecConfig::sw_nonlinearities(ExpAlgo::Exps),
        &trace_attention_core(&mb),
    );
    println!(
        "attention layer @seq512: SoftEx {:.0} GOPS (paper 324), sw {:.0} GOPS, slowdown {:.2}x (paper >2.17x)",
        hw.gops(&OP_THROUGHPUT),
        sw.gops(&OP_THROUGHPUT),
        sw.total_cycles() as f64 / hw.total_cycles() as f64
    );
    println!(
        "attention layer efficiency @0.55V: {:.2} TOPS/W (paper 1.30)",
        hw.tops_per_w(&OP_EFFICIENCY)
    );

    let full = execute_trace(&ExecConfig::paper_accelerated(), &trace_model(&mb));
    println!(
        "full MobileBERT (24 layers, seq 512): {:.0} GOPS, {:.0} ms (paper: 297 GOPS, 152 ms)",
        full.gops(&OP_THROUGHPUT),
        full.seconds(&OP_THROUGHPUT) * 1e3
    );
    println!("mobilebert_attention OK");
}
