//! The IR-only workloads end to end: Llama-edge (causal decoder, GQA
//! 32q/8kv, RMSNorm, SwiGLU) and Whisper-tiny-enc (1500-frame encoder)
//! served through the single-mesh scheduler and the fleet dispatcher —
//! the same paths the legacy ViT/MobileBERT/GPT-2 XL presets use,
//! with no model-specific code anywhere below the workload IR.
//!
//! Run: cargo run --release --example new_workloads

use softex::energy::OP_THROUGHPUT;
use softex::fleet::{DispatchPolicy, Fleet, FleetConfig};
use softex::report;
use softex::server::{
    summary_table, ArrivalProcess, BatchScheduler, Policy, RequestGen, ServeReport, ServerConfig,
    WorkloadMix,
};
use softex::sim::{kv, KvConfig};
use softex::workload::ModelConfig;

fn main() {
    let seed = 0x11A3A;

    // --- GQA shrinks the KV working set -------------------------------
    let llama = ModelConfig::llama_edge();
    let mha = ModelConfig { kv_heads: llama.heads, ..llama.clone() };
    println!(
        "KV cache per token/layer: {} B with GQA {}q/{}kv vs {} B as MHA \
         => {}x more TCDM-resident context",
        kv::kv_bytes_per_token(&llama),
        llama.heads,
        llama.kv_heads,
        kv::kv_bytes_per_token(&mha),
        kv::kv_bytes_per_token(&mha) / kv::kv_bytes_per_token(&llama),
    );

    // --- serve: each new model as a single-model stream ---------------
    let mut reports = Vec::new();
    for name in ["llama-edge", "whisper-tiny-enc"] {
        let mix = WorkloadMix::for_model(name).expect("preset");
        for policy in [Policy::Fifo, Policy::ContinuousBatching] {
            let reqs = RequestGen::new(
                seed,
                ArrivalProcess::Poisson { mean_gap: 4.0e6 },
                mix.clone(),
            )
            .generate(120);
            let mut cfg = ServerConfig::new(2, policy);
            cfg.kv = KvConfig::tcdm_spill();
            let mut rep = BatchScheduler::new(cfg).run(&reqs);
            rep.label = format!("{name}/{}", policy.label());
            reports.push(rep);
        }
    }
    println!(
        "{}",
        summary_table("new workloads on a 2x2 mesh (KV spill model)", &reports)
    );
    for rep in &reports {
        if rep.kv_spill_bytes > 0 {
            println!(
                "{}: {:.1} MiB KV spill, tbt p95 {} ms",
                rep.label,
                rep.kv_spill_bytes as f64 / (1024.0 * 1024.0),
                report::f(ServeReport::ms(rep.tbt_p95(), &OP_THROUGHPUT), 2)
            );
        }
    }
    println!();

    // --- fleet: the GenAI-heavy mix across 8 clusters -----------------
    let requests = RequestGen::new(
        seed,
        ArrivalProcess::Poisson { mean_gap: 6.0e5 },
        WorkloadMix::genai_default(),
    )
    .generate(300);
    let run_with = |threads: usize| {
        let mut cfg = FleetConfig::new(8, DispatchPolicy::PowerOfTwoChoices);
        cfg.seed = seed;
        cfg.threads = threads;
        Fleet::new(cfg).run(&requests)
    };
    let rep = run_with(2);
    println!("{}", rep.render());

    // --- determinism contract stays intact for the new IR presets -----
    let again = run_with(8);
    assert_eq!(rep.latencies, again.latencies, "2 vs 8 threads");
    assert_eq!(rep.ttft, again.ttft);
    assert_eq!(rep.tbt, again.tbt);
    println!(
        "determinism: genai mix identical across 2/8 worker threads, p99 = {} ms",
        report::f(ServeReport::ms(rep.p99(), &OP_THROUGHPUT), 2)
    );
    println!("new workloads OK");
}
