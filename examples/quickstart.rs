//! Quickstart: the three-layer flow in one page.
//!
//! 1. Load an AOT JAX/Pallas artifact (L1+L2, compiled by `make
//!    artifacts`) through the PJRT runtime and execute it from Rust.
//! 2. Run the same softmax on the bit-accurate SoftEx hardware model and
//!    compare outputs.
//! 3. Ask the cycle/energy model what the job costs on the cluster.
//!
//! Run: cargo run --release --example quickstart

use softex::energy::{energy_j, ActivityMode, OP_THROUGHPUT};
use softex::report;
use softex::runtime::Engine;
use softex::softex::{run_softmax, SoftExConfig};
use softex::workload::gen;

fn main() -> anyhow::Result<()> {
    // --- 1. request-path execution of the Pallas softmax kernel --------
    let mut engine = Engine::from_default_artifacts()?;
    let rows = 128;
    let len = 128;
    let scores = gen::attention_scores(rows, len, 42);
    let pallas_out = engine.run("softmax_128x128", &[scores.clone()])?;
    println!("PJRT softmax_128x128: {} outputs", pallas_out.len());

    // --- 2. the same job on the SoftEx hardware model -------------------
    let cfg = SoftExConfig::default();
    let hw = run_softmax(&cfg, &scores, rows, len);
    let max_diff = hw
        .out
        .iter()
        .zip(&pallas_out)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!("SoftEx model vs Pallas kernel: max |diff| = {max_diff:.2e}");
    assert!(max_diff < 0.02, "cross-layer contract violated");

    // --- 3. what does it cost on the cluster? ---------------------------
    let e = energy_j(ActivityMode::SoftmaxHw, hw.cycles.total(), &OP_THROUGHPUT);
    println!(
        "cycle model: {} total ({} acc / {} inv / {} norm), {:.2} uJ @0.8V",
        report::cycles(hw.cycles.total()),
        report::cycles(hw.cycles.accumulation),
        report::cycles(hw.cycles.inversion),
        report::cycles(hw.cycles.normalization),
        e * 1e6
    );
    println!("quickstart OK");
    Ok(())
}
