//! Quickstart: the three-layer flow in one page.
//!
//! 1. Run a softmax job on the bit-accurate SoftEx hardware model.
//! 2. Cross-check against the AOT JAX/Pallas artifact through the PJRT
//!    runtime (skipped with a note when the artifacts or the PJRT
//!    backend are unavailable — see DESIGN.md §4).
//! 3. Ask the cycle/energy model what the job costs on the cluster.
//!
//! Run: cargo run --release --example quickstart

use softex::energy::{energy_j, ActivityMode, OP_THROUGHPUT};
use softex::report;
use softex::runtime::Engine;
use softex::softex::{run_softmax, SoftExConfig, SoftmaxResult};
use softex::workload::gen;

fn pjrt_cross_check(scores: &[f32], hw: &SoftmaxResult) -> softex::anyhow::Result<()> {
    let mut engine = Engine::from_default_artifacts()?;
    let pallas_out = engine.run("softmax_128x128", &[scores.to_vec()])?;
    println!("PJRT softmax_128x128: {} outputs", pallas_out.len());
    let max_diff = hw
        .out
        .iter()
        .zip(&pallas_out)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!("SoftEx model vs Pallas kernel: max |diff| = {max_diff:.2e}");
    assert!(max_diff < 0.02, "cross-layer contract violated");
    Ok(())
}

fn main() {
    // --- 1. the softmax job on the SoftEx hardware model ----------------
    let rows = 128;
    let len = 128;
    let scores = gen::attention_scores(rows, len, 42);
    let cfg = SoftExConfig::default();
    let hw = run_softmax(&cfg, &scores, rows, len);
    let worst = hw
        .out
        .chunks(len)
        .map(|row| (row.iter().sum::<f32>() - 1.0).abs())
        .fold(0.0f32, f32::max);
    println!("SoftEx softmax [{rows}x{len}]: worst |rowsum - 1| = {worst:.4}");

    // --- 2. cross-check against the Pallas kernel when available --------
    if let Err(e) = pjrt_cross_check(&scores, &hw) {
        println!("(PJRT cross-check skipped: {e})");
    }

    // --- 3. what does it cost on the cluster? ---------------------------
    let e = energy_j(ActivityMode::SoftmaxHw, hw.cycles.total(), &OP_THROUGHPUT);
    println!(
        "cycle model: {} total ({} acc / {} inv / {} norm), {:.2} uJ @0.8V",
        report::cycles(hw.cycles.total()),
        report::cycles(hw.cycles.accumulation),
        report::cycles(hw.cycles.inversion),
        report::cycles(hw.cycles.normalization),
        e * 1e6
    );
    println!("quickstart OK");
}
