//! The paper's accuracy analysis (Sec. VI), regenerated:
//!
//! * VI-A1: expp vs exps vs accurate exp — mean/max relative error;
//! * VI-A2: softmax output error on 1024-element attention-score vectors;
//! * VI-B : GELU sum-of-exponentials — terms x accumulator-bits sweep.
//!
//! Run: cargo run --release --example accuracy_sweep

use softex::expp::error::sweep_exp;
use softex::expp::{exp_accurate, expp, exps};
use softex::report;
use softex::softex::coeffs::gelu_ref;
use softex::softex::gelu::run_gelu;
use softex::softex::{run_softmax, SoftExConfig};
use softex::workload::gen;

fn main() {
    // --- exponential approximation (paper: expp 0.14%/0.78%) ------------
    let n = 2_000_000;
    let rows: Vec<Vec<String>> = [
        ("accurate (glibc role)", sweep_exp(exp_accurate, -87.0, 88.0, n, 1)),
        ("expp (Sec. IV)", sweep_exp(expp, -87.0, 88.0, n, 1)),
        ("exps (Schraudolph)", sweep_exp(exps, -87.0, 88.0, n, 1)),
    ]
    .iter()
    .map(|(name, s)| {
        vec![
            name.to_string(),
            format!("{:.3}%", s.mean_pct()),
            format!("{:.3}%", s.max_pct()),
        ]
    })
    .collect();
    println!(
        "{}",
        report::render_table(
            "Sec. VI-A1 — exponential relative error (paper: expp 0.14%/0.78%, 13x/3.7x vs exps)",
            &["algorithm", "mean rel err", "max rel err"],
            &rows
        )
    );

    // --- softmax accuracy on 1024-long vectors ---------------------------
    let scores = gen::attention_scores(64, 1024, 7);
    let cfg = SoftExConfig::default();
    let hw = run_softmax(&cfg, &scores, 64, 1024);
    let mut rel = (0.0f64, 0u64);
    for (row_in, row_out) in scores.chunks(1024).zip(hw.out.chunks(1024)) {
        let m = row_in.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
        let e: Vec<f64> = row_in.iter().map(|&x| ((x as f64) - m).exp()).collect();
        let s: f64 = e.iter().sum();
        for (&got, want) in row_out.iter().zip(e.iter().map(|v| v / s)) {
            if want > 1e-5 {
                rel.0 += ((got as f64 - want) / want).abs();
                rel.1 += 1;
            }
        }
    }
    println!(
        "Sec. VI-A2 — softmax MRE on 1024-long vectors: {:.2}% (paper: 0.44%, 3.2x better than exps)\n",
        100.0 * rel.0 / rel.1 as f64
    );

    // --- GELU terms x bits sweep (Fig. 5) --------------------------------
    let xs = gen::gelu_inputs(65536, 11);
    let exact: Vec<f64> = xs.iter().map(|&x| gelu_ref(x as f64)).collect();
    let mut rows = Vec::new();
    for bits in [8u32, 10, 11, 12, 14, 16] {
        let mut row = vec![format!("{bits} bits")];
        for terms in 2..=6 {
            let c = SoftExConfig { terms, acc_frac_bits: bits, ..Default::default() };
            let out = run_gelu(&c, &xs);
            let mse: f64 = out
                .out
                .iter()
                .zip(&exact)
                .map(|(&y, &w)| (y as f64 - w) * (y as f64 - w))
                .sum::<f64>()
                / xs.len() as f64;
            row.push(format!("{mse:.2e}"));
        }
        rows.push(row);
    }
    println!(
        "{}",
        report::render_table(
            "Fig. 5 — GELU MSE vs exact, accumulator bits x sum-of-exp terms (knee at 11 bits / 4 terms)",
            &["acc width", "2 terms", "3 terms", "4 terms", "5 terms", "6 terms"],
            &rows
        )
    );
    println!("accuracy_sweep OK");
}
